"""Pallas paged-attention decode kernel vs the XLA gather oracle (interpret
mode) on ragged shapes, plus the block-paging storage-transform identity:
paged attention over scattered pages must equal dense ``decode_attention``
over the contiguous cache it represents."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import bp_matmul
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import gather_pages, paged_attention_xla
from repro.models.attention import decode_attention

jax.config.update("jax_default_matmul_precision", "float32")


def _case(seed, B, H, KH, D, n_blocks, bs, pages_per_seq, T_hi):
    """Random pages + a random block table/lengths per sequence (unused
    table entries point at the trash page 0)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (n_blocks, bs, KH, D), jnp.float32)
    vp = jax.random.normal(ks[2], (n_blocks, bs, KH, D), jnp.float32)
    rng = np.random.default_rng(seed)
    bt = np.zeros((B, pages_per_seq), np.int32)
    lengths = np.zeros(B, np.int32)
    for b in range(B):
        lengths[b] = rng.integers(0, T_hi)
        n_used = lengths[b] // bs + 1
        bt[b, :n_used] = rng.choice(
            np.arange(1, n_blocks), size=n_used, replace=False)
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths)


RAGGED = [
    # B, H, KH, D, n_blocks, bs, pages_per_seq, T_hi
    (1, 2, 1, 8, 6, 4, 4, 16),
    (3, 4, 2, 16, 12, 4, 5, 20),
    (5, 6, 3, 32, 20, 8, 3, 24),
    (2, 8, 8, 16, 10, 2, 7, 14),     # MHA (G = 1), tiny blocks
    (4, 4, 1, 64, 16, 16, 2, 32),    # MQA-style, one kv head
]


@pytest.mark.parametrize("B,H,KH,D,n_blocks,bs,pps,T_hi", RAGGED)
def test_kernel_matches_xla_oracle(B, H, KH, D, n_blocks, bs, pps, T_hi):
    q, kp, vp, bt, lens = _case(hash((B, H, KH, D)) % 2**31, B, H, KH, D,
                                n_blocks, bs, pps, T_hi)
    want = paged_attention_xla(q, kp, vp, bt, lens)
    got = paged_attention_kernel(q, kp, vp, bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_oracle_equals_dense_decode_attention():
    """Block paging is a pure storage transform: gathering the pages of a
    sequence and running the slab ``decode_attention`` must give the same
    output as paged attention over the scattered pages."""
    B, H, KH, D, n_blocks, bs, pps = 3, 4, 2, 16, 14, 4, 5
    q, kp, vp, bt, lens = _case(11, B, H, KH, D, n_blocks, bs, pps, 18)
    paged = paged_attention_xla(q, kp, vp, bt, lens)
    k_dense = gather_pages(kp, bt)     # (B, pps*bs, KH, D)
    v_dense = gather_pages(vp, bt)
    dense = decode_attention(q[:, None], k_dense, v_dense, lens)[:, 0]
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


def test_int8_scale_pages_route_and_match():
    """int8 KV scale pages take the XLA path in every backend and apply the
    exact per-token-per-head scale factorization of ``decode_attention``."""
    B, H, KH, D, n_blocks, bs, pps = 2, 4, 2, 16, 10, 4, 4
    q, kp, vp, bt, lens = _case(5, B, H, KH, D, n_blocks, bs, pps, 14)
    kq = jnp.round(jnp.clip(kp * 20, -127, 127)).astype(jnp.int8)
    vq = jnp.round(jnp.clip(vp * 20, -127, 127)).astype(jnp.int8)
    ks = jnp.abs(jax.random.normal(jax.random.PRNGKey(3),
                                   (n_blocks, bs, KH))) + 0.01
    vs = jnp.abs(jax.random.normal(jax.random.PRNGKey(4),
                                   (n_blocks, bs, KH))) + 0.01
    with bp_matmul.use_matmul_backend("kernel_interpret"):
        got = paged_attention(q, kq, vq, bt, lens,
                              k_scale_pages=ks, v_scale_pages=vs)
    k_d, v_d = gather_pages(kq, bt), gather_pages(vq, bt)
    ks_d, vs_d = gather_pages(ks, bt), gather_pages(vs, bt)
    want = decode_attention(q[:, None], k_d, v_d, lens,
                            k_scale=ks_d, v_scale=vs_d)[:, 0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_backend_dispatch_interpret_vs_xla():
    """The public op under ``kernel_interpret`` matches the ``xla`` backend
    (the engine scopes exactly this switch around its decode traces)."""
    B, H, KH, D, n_blocks, bs, pps = 3, 6, 3, 32, 12, 8, 3
    q, kp, vp, bt, lens = _case(21, B, H, KH, D, n_blocks, bs, pps, 20)
    with bp_matmul.use_matmul_backend("xla"):
        want = paged_attention(q, kp, vp, bt, lens)
    with bp_matmul.use_matmul_backend("kernel_interpret"):
        got = paged_attention(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_all_zero_length_row_is_finite():
    """A fresh slot (length 0, table all trash) must still produce finite
    output — only position 0 of the trash page is unmasked."""
    B, H, KH, D, n_blocks, bs, pps = 2, 2, 1, 8, 6, 4, 3
    q, kp, vp, _, _ = _case(31, B, H, KH, D, n_blocks, bs, pps, 10)
    bt = jnp.zeros((B, pps), jnp.int32)
    lens = jnp.zeros(B, jnp.int32)
    for backend in ("xla", "kernel_interpret"):
        with bp_matmul.use_matmul_backend(backend):
            out = paged_attention(q, kp, vp, bt, lens)
        assert bool(jnp.isfinite(out).all())
