"""Approximation-depth ablation invariants (benchmarks/ablation_drop_groups):
error/skip monotonicity and the paper-point identities."""

import pytest

from benchmarks.ablation_drop_groups import run


@pytest.fixture(scope="module")
def result():
    return run()


def test_paper_points(result):
    rows = {r["dropped_groups"]: r for r in result["rows"]}
    assert rows[0]["max_abs_error"] == 0           # exact is exact
    assert rows[2]["max_abs_error"] == 81          # paper bound
    assert rows[1]["max_abs_error"] == 9           # group {0} alone


def test_monotone_tradeoff(result):
    rows = result["rows"]
    errs = [r["mean_rel_error"] for r in rows]
    cycles = [r["avg_cycles_bs0.65"] for r in rows]
    skipped = [r["skipped_calc_frac"] for r in rows]
    mse = [r["layer_logit_rel_mse"] for r in rows]
    assert all(a <= b + 1e-12 for a, b in zip(errs, errs[1:]))
    assert all(a >= b - 1e-9 for a, b in zip(cycles, cycles[1:]))
    assert all(a <= b + 1e-12 for a, b in zip(skipped, skipped[1:]))
    assert all(a <= b + 1e-12 for a, b in zip(mse, mse[1:]))


def test_knee_is_at_the_paper_choice(result):
    # dropping a third group blows error up far faster than it saves cycles
    assert result["third_group_error_blowup"] > 4
    assert result["third_group_cycle_gain"] < 0.2
