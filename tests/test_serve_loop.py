"""ServeLoop components in isolation: the former ``serve()`` closures
(submit_arrivals / pick_victim / preempt / insert_with_preemption / admit)
are methods now, unit-tested directly instead of only end-to-end."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (Request, RequestState, ServeConfig, ServeLoop,
                           ServingEngine)

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16, **kw)


def _engine(cfg, max_new=8, backend="slab", block_size=4):
    params = api.init(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, params,
                         ServeConfig(max_new_tokens=max_new, temperature=0.0,
                                     cache_backend=backend,
                                     block_size=block_size))


def _prompt(cfg, S, seed=1):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (S,), 2,
                                         cfg.vocab_size), np.int32)


def _decode_state(req):
    """Walk a WAITING request to DECODE (as admit() would)."""
    req.transition(RequestState.PREFILL)
    req.transition(RequestState.DECODE)
    return req


class TestSubmitArrivals:
    def test_only_due_arrivals_enter_the_queue(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        reqs = [Request(prompt=_prompt(cfg, 4), max_new_tokens=2,
                        arrival_time=t) for t in (0.0, 0.0, 7.0)]
        loop = engine.make_loop(reqs, n_slots=2)
        loop.submit_arrivals()
        assert len(loop.rq) == 2 and len(loop.arrivals) == 1
        # the clock advances past the straggler: it enters too
        loop.now = 7.0
        loop.submit_arrivals()
        assert len(loop.rq) == 3 and not loop.arrivals

    def test_oversized_arrival_rejected_not_queued(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        ok = Request(prompt=_prompt(cfg, 4), max_new_tokens=2)
        big = Request(prompt=_prompt(cfg, 4), max_new_tokens=64)
        loop = engine.make_loop([ok, big], n_slots=2, cache_T=8)
        loop.submit_arrivals()
        assert len(loop.rq) == 1
        assert big.finish_reason == "rejected"
        assert loop.rq.n_rejected == 1


class TestPreemption:
    def test_pick_victim_prefers_most_recent_admission(self):
        cfg = _dense_cfg()
        loop = _engine(cfg).make_loop([], n_slots=4)
        for slot, admitted_at in ((0, 1.0), (1, 5.0), (2, 3.0)):
            req = Request(prompt=_prompt(cfg, 4), max_new_tokens=4)
            req.admitted_at = admitted_at
            loop.active[slot] = req
        assert loop.pick_victim() == 1          # newest admission
        # tie on admitted_at: the larger request_id (newer request) goes
        tie = Request(prompt=_prompt(cfg, 4), max_new_tokens=4)
        tie.admitted_at = 5.0
        loop.active[3] = tie
        assert loop.pick_victim() == 3

    def test_pick_victim_empty_pool_returns_none(self):
        loop = _engine(_dense_cfg()).make_loop([], n_slots=2)
        assert loop.pick_victim() is None

    def test_preempt_requeues_at_head_with_replay(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        # an already-waiting request sits in the queue; the preempted one
        # must cut in FRONT of it
        waiting = Request(prompt=_prompt(cfg, 4), max_new_tokens=4)
        loop = engine.make_loop([waiting], n_slots=2)
        loop.submit_arrivals()
        victim = _decode_state(Request(prompt=_prompt(cfg, 4),
                                       max_new_tokens=6))
        victim.tokens = [11, 22]
        slot = loop.cm.alloc()
        loop.active[slot] = victim
        loop.preempt(slot)
        assert victim.state is RequestState.WAITING
        assert victim.replay == [11, 22] and victim.tokens == []
        assert victim.n_preemptions == 1
        assert loop.n_preemptions == 1
        assert slot not in loop.active and loop.cm.n_free == 2
        assert loop.rq.peek()[0] is victim      # queue head

    def test_insert_with_preemption_evicts_newest_until_fit(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, backend="paged", block_size=4)
        # pool of 3 usable blocks; an 8-token prompt needs 2
        first = Request(prompt=_prompt(cfg, 8, seed=2), max_new_tokens=4)
        loop = engine.make_loop([first], n_slots=2, cache_T=12, num_blocks=4)
        loop.submit_arrivals()
        for group in loop.sched.plan_admissions():
            loop.admit(group)
        assert list(loop.active.values()) == [first]
        # a second 8-token prompt (different tokens: no prefix hits) cannot
        # fit the remaining 1 block -> the first request gets preempted
        second = Request(prompt=_prompt(cfg, 8, seed=3), max_new_tokens=4)
        second.transition(RequestState.PREFILL)
        second.admitted_at = loop.now
        _, cache = engine.executor.prefill(
            {"tokens": np.asarray(second.prompt)[None]}, loop.cache_T)
        slot = loop.cm.alloc()
        loop.insert_with_preemption(slot, cache, second, 0)
        assert loop.n_preemptions == 1
        assert first.state is RequestState.WAITING
        assert loop.rq.peek()[0] is first

    def test_insert_with_preemption_raises_with_no_victims(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, backend="paged", block_size=4)
        req = Request(prompt=_prompt(cfg, 12, seed=2), max_new_tokens=4)
        loop = engine.make_loop([], n_slots=2, cache_T=16, num_blocks=3)
        req.transition(RequestState.PREFILL)
        _, cache = engine.executor.prefill(
            {"tokens": np.asarray(req.prompt)[None]}, loop.cache_T)
        slot = loop.cm.alloc()
        # 12 tokens need 3 blocks; only 2 usable exist and nothing can be
        # preempted -> a clear error, not a wedge
        with pytest.raises(RuntimeError, match="num_blocks"):
            loop.insert_with_preemption(slot, cache, req, 0)


class TestAdmit:
    def test_admit_samples_first_token_and_occupies_slot(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new=4)
        req = Request(prompt=_prompt(cfg, 5), max_new_tokens=4)
        loop = engine.make_loop([req], n_slots=2)
        loop.submit_arrivals()
        groups = loop.sched.plan_admissions()
        assert [len(g) for g in groups] == [1]
        loop.admit(groups[0])
        assert req.state is RequestState.DECODE
        assert len(req.tokens) == 1 and req.first_token_at == 0.0
        assert loop.active[req.slot] is req
        assert loop.last_tok[req.slot] == req.tokens[0]
        # the sampled token matches the static engine's first token
        static = engine.generate({"tokens": jnp.asarray(
            np.asarray(req.prompt)[None])}, max_new_tokens=1)
        assert req.tokens[0] == int(static.tokens[0, 0])

    def test_admit_replay_forces_recorded_token(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new=4)
        req = Request(prompt=_prompt(cfg, 5), max_new_tokens=4)
        req.replay = [42, 17]      # as a preemption would leave behind
        loop = engine.make_loop([req], n_slots=2)
        loop.submit_arrivals()
        loop.admit(loop.sched.plan_admissions()[0])
        assert req.tokens == [42]       # forced, not resampled
        assert req.replay == [17]       # remaining tail replays in decode

    def test_admit_finishing_first_token_never_takes_a_slot(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new=1)
        req = Request(prompt=_prompt(cfg, 5), max_new_tokens=1)
        loop = engine.make_loop([req], n_slots=2)
        loop.submit_arrivals()
        loop.admit(loop.sched.plan_admissions()[0])
        assert req.state is RequestState.DONE
        assert req.finish_reason == "length"
        assert not loop.active and loop.cm.n_free == 2


class TestStepping:
    def test_writable_slots_slab_is_passthrough(self):
        cfg = _dense_cfg()
        loop = _engine(cfg).make_loop([], n_slots=3)
        for slot in (0, 2):
            loop.active[slot] = _decode_state(
                Request(prompt=_prompt(cfg, 4), max_new_tokens=4))
        assert sorted(loop.writable_slots()) == [0, 2]

    def test_run_equals_engine_serve(self):
        # the loop object and engine.serve() are the same machinery
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new=6)
        prompts = [_prompt(cfg, 5, seed=s) for s in (1, 2, 3)]
        mk = lambda: [Request(prompt=p, max_new_tokens=6,
                              arrival_time=float(i))
                      for i, p in enumerate(prompts)]
        direct = ServeLoop(engine, mk(), n_slots=2).run()
        via_engine = engine.serve(mk(), n_slots=2)
        for a, b in zip(sorted(direct.results, key=lambda r: r.request_id),
                        sorted(via_engine.results,
                               key=lambda r: r.request_id)):
            np.testing.assert_array_equal(a.tokens, b.tokens)
