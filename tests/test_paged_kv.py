"""Paged KV-cache backend vs the slab backend: greedy token-identity across
workloads (staggered, heterogeneous, shared-prefix, int8 KV, MoE),
prefix-sharing block savings, copy-on-write, preemption-and-requeue, and the
power-of-two prefill bucketing satellite."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (QuasiSyncScheduler, Request, RequestQueue,
                           SchedulerConfig, ServeConfig, ServingEngine,
                           make_cache_manager)
from repro.serving.scheduler import prefill_bucket_len

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16, **kw)


def _engine(cfg, backend, max_new=8, block_size=4, eos=None, seed=0):
    params = api.init(jax.random.PRNGKey(seed), cfg)
    return ServingEngine(cfg, params,
                         ServeConfig(max_new_tokens=max_new, temperature=0.0,
                                     eos_id=eos, cache_backend=backend,
                                     block_size=block_size))


def _prompts(cfg, B, S, seed=1):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (B, S), 2,
                           cfg.vocab_size), np.int32)


def _assert_same_results(report_a, report_b):
    ra = sorted(report_a.results, key=lambda r: r.request_id)
    rb = sorted(report_b.results, key=lambda r: r.request_id)
    for a, b in zip(ra, rb):
        assert a.finish_reason == b.finish_reason
        assert len(a.tokens) == len(b.tokens), (a.tokens, b.tokens)
        np.testing.assert_array_equal(a.tokens, b.tokens)


def _both(cfg, reqs_fn, *, max_new=8, eos=None, seed=0, **serve_kw):
    slab = _engine(cfg, "slab", max_new=max_new, eos=eos, seed=seed)
    paged = _engine(cfg, "paged", max_new=max_new, eos=eos, seed=seed)
    r_slab = slab.serve(reqs_fn(), **{k: v for k, v in serve_kw.items()
                                      if k != "num_blocks"})
    r_paged = paged.serve(reqs_fn(), **serve_kw)
    _assert_same_results(r_slab, r_paged)
    return r_slab, r_paged


# ---------------------------------------------------------------------------
# Token identity: paged must reproduce the slab outputs exactly
# ---------------------------------------------------------------------------

class TestPagedTokenIdentity:
    def test_simultaneous_arrivals(self):
        cfg = _dense_cfg()
        prompts = _prompts(cfg, 4, 6)
        _both(cfg, lambda: [Request(prompt=prompts[i], max_new_tokens=8)
                            for i in range(4)], n_slots=4)

    def test_staggered_hetero_lengths(self):
        cfg = _dense_cfg()
        prompts = _prompts(cfg, 5, 6)
        max_news = [8, 3, 8, 5, 1]
        _both(cfg,
              lambda: [Request(prompt=prompts[i], max_new_tokens=max_news[i],
                               arrival_time=float(i)) for i in range(5)],
              n_slots=2, sched_cfg=SchedulerConfig(lead_window=2))

    def test_hetero_prompt_lengths(self):
        cfg = _dense_cfg()
        lens = [3, 7, 5, 9]
        prompts = [_prompts(cfg, 1, L, seed=L)[0] for L in lens]
        _both(cfg,
              lambda: [Request(prompt=prompts[i], max_new_tokens=5,
                               arrival_time=float(i)) for i in range(4)],
              n_slots=2)

    def test_eos_early_exit(self):
        cfg = _dense_cfg()
        prompts = _prompts(cfg, 3, 5)
        probe = _engine(cfg, "slab").generate(
            {"tokens": jnp.asarray(prompts)}, max_new_tokens=4)
        eos = int(np.asarray(probe.tokens)[0, -1])   # hit by request 0
        _both(cfg, lambda: [Request(prompt=prompts[i], max_new_tokens=8)
                            for i in range(3)],
              n_slots=3, eos=eos)

    def test_int8_kv_cache(self):
        cfg = _dense_cfg(kv_cache_int8=True)
        prompts = _prompts(cfg, 3, 7)
        _both(cfg, lambda: [Request(prompt=prompts[i], max_new_tokens=5,
                                    arrival_time=float(i)) for i in range(3)],
              n_slots=2)

    def test_moe_family(self):
        cfg = get_arch("granite-moe-1b-a400m").reduced().replace(
            num_layers=2, d_model=64, vocab_size=128, head_dim=16)
        prompts = _prompts(cfg, 3, 6)
        _both(cfg, lambda: [Request(prompt=prompts[i], max_new_tokens=4,
                                    arrival_time=float(i)) for i in range(3)],
              n_slots=2)

    def test_matches_static_generate(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, "paged")
        prompts = _prompts(cfg, 4, 6)
        report = engine.serve([Request(prompt=prompts[i], max_new_tokens=6)
                               for i in range(4)], n_slots=4)
        static = engine.generate({"tokens": jnp.asarray(prompts)},
                                 max_new_tokens=6)
        for i, r in enumerate(sorted(report.results,
                                     key=lambda r: r.request_id)):
            np.testing.assert_array_equal(r.tokens, np.asarray(static.tokens[i]))


# ---------------------------------------------------------------------------
# Memory behavior: sharing, CoW, preemption, elastic admission
# ---------------------------------------------------------------------------

class TestPagedMemoryBehavior:
    def test_shared_prefix_saves_blocks_and_hits_counted(self):
        cfg = _dense_cfg()
        sys_prompt = _prompts(cfg, 1, 12, seed=9)[0]
        uniq = _prompts(cfg, 4, 3, seed=10)
        prompts = [np.concatenate([sys_prompt, uniq[i]]) for i in range(4)]
        reqs = lambda: [Request(prompt=prompts[i], max_new_tokens=4,
                                arrival_time=float(2 * i)) for i in range(4)]
        _, rp = _both(cfg, reqs, max_new=4, n_slots=4)
        assert rp.prefix_hit_blocks > 0
        # 3 followers x 3 shared full blocks of 4 tokens each
        assert rp.prefix_hit_blocks >= 9
        unique_ids = _engine(cfg, "paged", max_new=4).serve(
            [Request(prompt=_prompts(cfg, 1, 15, seed=20 + i)[0],
                     max_new_tokens=4, arrival_time=float(2 * i))
             for i in range(4)], n_slots=4)
        assert rp.peak_blocks_in_use < unique_ids.peak_blocks_in_use

    def test_partial_prefix_copy_on_write(self):
        cfg = _dense_cfg()
        base = _prompts(cfg, 1, 16, seed=5)[0]
        prompts = [base, base[:14]]     # 14 = 3 full blocks + 2-token tail
        _, rp = _both(cfg,
                      lambda: [Request(prompt=prompts[i], max_new_tokens=6,
                                       arrival_time=float(3 * i))
                               for i in range(2)],
                      max_new=6, n_slots=2)
        assert rp.cow_blocks >= 1

    def test_pool_dry_preempts_and_replays(self):
        cfg = _dense_cfg()
        prompts = _prompts(cfg, 3, 8, seed=3)
        reqs = lambda: [Request(prompt=prompts[i], max_new_tokens=8,
                                arrival_time=0.0) for i in range(3)]
        _, rp = _both(cfg, reqs, max_new=8, n_slots=3, cache_T=24,
                      num_blocks=9)
        assert rp.n_preemptions > 0
        assert all(r.finish_reason in ("eos", "length") for r in rp.results)

    def test_admission_is_block_elastic_not_worst_case(self):
        """At a fixed HBM budget a shared-prefix workload admits more
        concurrently on paged than the slab's worst-case reservation."""
        cfg = _dense_cfg()
        sys_prompt = _prompts(cfg, 1, 12, seed=9)[0]
        uniq = _prompts(cfg, 6, 2, seed=11)
        prompts = [np.concatenate([sys_prompt, uniq[i]]) for i in range(6)]
        # budget: 2 slab slots' worth of tokens (2 * 32 = 64 tokens)
        cache_T = 14 + 8 + 8   # prompt + new + margin -> rounds to 32
        reqs = lambda: [Request(prompt=prompts[i], max_new_tokens=8,
                                arrival_time=float(i)) for i in range(6)]
        slab = _engine(cfg, "slab").serve(reqs(), n_slots=2, cache_T=cache_T)
        paged = _engine(cfg, "paged").serve(
            reqs(), n_slots=6, cache_T=cache_T,
            num_blocks=1 + 2 * cache_T // 4)     # same token budget
        _assert_same_results(slab, paged)
        assert paged.steps < slab.steps          # more concurrency, fewer steps

    def test_paged_rejects_recurrent_families(self):
        cfg = get_arch("rwkv6-7b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        with pytest.raises(ValueError, match="slab"):
            make_cache_manager(cfg, 2, 16, backend="paged")


# ---------------------------------------------------------------------------
# Power-of-two prefill bucketing (scheduler satellite)
# ---------------------------------------------------------------------------

class TestPow2Bucketing:
    def test_bucket_lengths(self):
        assert [prefill_bucket_len(L) for L in (1, 2, 3, 5, 8, 9, 17)] == \
            [1, 2, 4, 8, 8, 16, 32]
        assert prefill_bucket_len(9, cache_T=12) == 12   # clamped

    def test_hetero_lengths_fuse_into_one_prefill_sync(self):
        """Prompts of length 5/6/7/8 land in one pow2 bucket (8): one
        prefill group; exact bucketing needs four."""
        cfg = _dense_cfg()

        def n_groups(bucketing):
            cm = make_cache_manager(cfg, 4, 24, backend="slab")
            rq = RequestQueue()
            sched = QuasiSyncScheduler(rq, cm, SchedulerConfig(
                prefill_bucketing=bucketing))
            for L in (5, 6, 7, 8):
                rq.submit(Request(prompt=np.arange(2, 2 + L), max_new_tokens=2))
            return len(sched.plan_admissions())

        assert n_groups("pow2") == 1
        assert n_groups("exact") == 4

    def test_bucketed_outputs_identical_to_exact(self):
        cfg = _dense_cfg()
        lens = [5, 6, 7, 3]
        prompts = [_prompts(cfg, 1, L, seed=40 + L)[0] for L in lens]

        def run(bucketing):
            eng = _engine(cfg, "slab", max_new=5)
            reqs = [Request(prompt=prompts[i], max_new_tokens=5,
                            arrival_time=0.0) for i in range(4)]
            return eng.serve(reqs, n_slots=4, sched_cfg=SchedulerConfig(
                prefill_bucketing=bucketing))

        _assert_same_results(run("exact"), run("pow2"))

    def test_bucketing_reduces_syncs_on_hetero_burst(self):
        cfg = _dense_cfg()
        lens = [5, 6, 7, 8]
        prompts = [_prompts(cfg, 1, L, seed=50 + L)[0] for L in lens]

        def run(bucketing):
            eng = _engine(cfg, "slab", max_new=4)
            reqs = [Request(prompt=prompts[i], max_new_tokens=4,
                            arrival_time=0.0) for i in range(4)]
            return eng.serve(reqs, n_slots=2, sched_cfg=SchedulerConfig(
                prefill_bucketing=bucketing, lead_window=0,
                max_prefill_batch=4))

        # same token streams, same number of *syncs* is allowed to shrink;
        # outputs must agree either way
        _assert_same_results(run("exact"), run("pow2"))

    def test_recurrent_families_default_to_exact(self):
        cfg = get_arch("rwkv6-7b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        params = api.init(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=3))
        prompts = [_prompts(cfg, 1, L, seed=60 + L)[0] for L in (3, 5)]
        report = engine.serve(
            [Request(prompt=prompts[i], max_new_tokens=3, arrival_time=0.0)
             for i in range(2)], n_slots=2)
        # per-request solo decode must match (right padding would break this)
        for i, r in enumerate(sorted(report.results,
                                     key=lambda r: r.request_id)):
            solo = engine.generate({"tokens": jnp.asarray(prompts[i][None])},
                                   max_new_tokens=3)
            np.testing.assert_array_equal(r.tokens, np.asarray(solo.tokens[0]))

    def test_ragged_prefill_rejected_for_recurrent(self):
        cfg = get_arch("rwkv6-7b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        params = api.init(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": np.zeros((2, 8), np.int32)}
        with pytest.raises(ValueError, match="recurrent"):
            api.prefill(params, cfg, batch, 16,
                        prompt_lens=jnp.asarray([4, 8]))
