"""End-to-end serving driver: a stream of requests with Poisson arrivals
against a small qwen2-family model with BitParticle W8A8 weights and an int8
KV cache, served by the quasi-sync continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py [--tokens 24] [--requests 8]
    PYTHONPATH=src python examples/serve_lm.py --mode bf16 --lead-window 0
    PYTHONPATH=src python examples/serve_lm.py --mesh 2x4   # TP over a mesh
    PYTHONPATH=src python examples/serve_lm.py --draft prompt_lookup
    PYTHONPATH=src python examples/serve_lm.py --draft model \
        --num-draft-tokens 4                  # speculative decoding
    PYTHONPATH=src python examples/serve_lm.py \
        --metrics run.jsonl --trace trace.json   # observability sinks
    PYTHONPATH=src python examples/serve_lm.py --probe 2 \
        --metrics run.jsonl   # measured bit-sparsity -> hw_estimate records
"""

import argparse
import os
import sys


def _parse_mesh(argv):
    """(data, model) from a ``--mesh DxM`` argument, or None.  Validates
    here (this runs before argparse, which only exists post-jax-init)."""
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith("--mesh="):
            val = a.split("=", 1)[1]
        else:
            continue
        parts = val.lower().split("x")
        if len(parts) != 2 or not all(p.isdigit() and int(p) > 0
                                      for p in parts):
            sys.exit(f"serve_lm: --mesh expects DATAxMODEL (e.g. 2x4), "
                     f"got {val!r}")
        return tuple(int(p) for p in parts)
    return None


# --mesh needs the virtual devices to exist BEFORE jax initializes its
# backend (device count is locked at first init), so this runs pre-import.
# The flag only affects the host/CPU platform; on real accelerators the
# mesh lays over the physical devices.
_MESH = _parse_mesh(sys.argv[1:])
if _MESH is not None and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_MESH[0] * _MESH[1]}")

import numpy as np
import jax

from repro.configs.base import get_arch
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving import (Request, SchedulerConfig, ServeConfig,
                           ServingEngine, SparsityProbe, Telemetry)


def main():
    # allow_abbrev=False: the pre-import XLA-flag scanner above only
    # recognizes the full `--mesh` spelling, so abbreviations must not
    # silently parse here with the devices never spawned
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24,
                    help="max new tokens per request")
    ap.add_argument("--rate", type=float, default=0.3,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--lead-window", type=int, default=4,
                    help="admission lead window E (0 = sync every step)")
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mode", default="bp_exact",
                    choices=["bf16", "bp_exact", "bp_approx"])
    ap.add_argument("--cache-backend", default="slab",
                    choices=["slab", "paged"],
                    help="decode-cache store: worst-case slab slots or "
                         "on-demand KV blocks with prefix sharing")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged backend)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve tensor-parallel over a (data, model) mesh, "
                         "e.g. 2x4 (spawns virtual CPU devices off-TPU)")
    ap.add_argument("--draft", default="none",
                    choices=["none", "prompt_lookup", "model"],
                    help="speculative decoding drafter: weight-free n-gram "
                         "prompt lookup, or a half-size same-family draft "
                         "model (greedy only — forces temperature 0)")
    ap.add_argument("--num-draft-tokens", type=int, default=4,
                    help="K: draft tokens verified per decode step")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="append one JSONL record per serving step "
                         "(docs/observability.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of serving spans — "
                         "load it in https://ui.perfetto.dev")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace into DIR "
                         "(view with tensorboard or perfetto)")
    ap.add_argument("--probe", type=int, default=0, metavar="K",
                    help="sample measured activation bit sparsity every "
                         "K-th decode step (0 = off) and fold it through "
                         "the paper's cost models — needs a bp_* --mode; "
                         "emits hw_estimate records when --metrics is set")
    args = ap.parse_args()
    mesh_shape = _MESH     # parsed+validated pre-import (sets XLA_FLAGS)
    if args.draft != "none" and args.temperature > 0:
        print(f"--draft {args.draft}: speculative decoding is greedy-only, "
              f"forcing --temperature 0")
        args.temperature = 0.0

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=4, d_model=256, d_ff=512, vocab_size=2048, head_dim=32)
    print(f"arch: qwen2-family reduced ({cfg.param_count()/1e6:.1f}M params), "
          f"mode={args.mode}")

    params = api.init(jax.random.PRNGKey(0), cfg)
    if args.mode != "bf16":
        params = quantize_dense_params(params)
        cfg = cfg.replace(matmul_mode=args.mode, kv_cache_int8=True)
        print("weights quantized to int8 (per-channel), KV cache int8")

    draft_cfg = draft_params = None
    if args.draft == "model":
        # half-size same-family drafter (qwen2-1.5b drafting for the larger
        # target, in spirit); random-init weights -> modest acceptance, the
        # machinery and accounting are what this example shows
        draft_cfg = cfg.replace(num_layers=2, d_model=128, d_ff=256,
                                head_dim=32)
        draft_params = api.init(jax.random.PRNGKey(7), draft_cfg)
        if args.mode != "bf16":
            draft_params = quantize_dense_params(draft_params)

    probe = None
    if args.probe > 0:
        if args.mode == "bf16":
            sys.exit("serve_lm: --probe taps int8 operands; use a bp_* "
                     "--mode")
        probe = SparsityProbe(probe_every=args.probe)

    engine = ServingEngine(cfg, params,
                           ServeConfig(max_new_tokens=args.tokens,
                                       temperature=args.temperature,
                                       cache_backend=args.cache_backend,
                                       block_size=args.block_size,
                                       mesh_shape=mesh_shape,
                                       draft=args.draft,
                                       num_draft_tokens=args.num_draft_tokens,
                                       probe=probe),
                           draft_cfg=draft_cfg, draft_params=draft_params)
    if mesh_shape is not None:
        print(f"mesh executor: {mesh_shape[0]}x{mesh_shape[1]} "
              f"(data, model) over {len(jax.devices())} devices — weights "
              f"TP-sharded, KV cache split per the decode recipe")

    rng = np.random.default_rng(0)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1),
                           (args.requests, args.prompt_len), 2,
                           cfg.vocab_size), np.int32)
    lo = min(max(1, args.tokens // 4), args.tokens)
    max_news = rng.integers(lo, args.tokens + 1, size=args.requests)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    requests = [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(args.requests)]

    # warmup (compile prefill + vector-cache_len decode) — runs BEFORE the
    # telemetry handle is attached so the sinks see only the measured serve
    engine.serve([Request(prompt=prompts[0], max_new_tokens=2)],
                 n_slots=args.slots,
                 cache_T=args.prompt_len + args.tokens
                 + engine.serve_cfg.cache_margin)

    tel = None
    if args.metrics or args.trace or args.profile_dir:
        import dataclasses
        tel = Telemetry(metrics_path=args.metrics, trace_path=args.trace,
                        profile_dir=args.profile_dir)
        engine.serve_cfg = dataclasses.replace(engine.serve_cfg,
                                               telemetry=tel)

    report = engine.serve(
        requests, n_slots=args.slots,
        cache_T=args.prompt_len + args.tokens + engine.serve_cfg.cache_margin,
        sched_cfg=SchedulerConfig(lead_window=args.lead_window))
    if tel is not None:
        tel.close()
        sinks = [p for p in (args.metrics, args.trace, args.profile_dir) if p]
        print(f"telemetry: {', '.join(sinks)}")

    print(f"\nserved {args.requests} requests on {args.slots} slots "
          f"(E={args.lead_window}, Poisson rate {args.rate}/step)")
    print(f"prefill: {report.prefill_s*1e3:.1f} ms across "
          f"{report.n_syncs} admission syncs")
    print(f"decode:  {report.steps} batched steps, "
          f"{report.decode_tokens_per_s:.1f} tokens/s, "
          f"{report.slot_utilization*100:.0f}% slot utilization, "
          f"max position divergence {report.max_divergence}")
    if report.cache_backend == "paged":
        print(f"paged:   peak {report.peak_blocks_in_use} blocks in use, "
              f"{report.prefix_hit_blocks} prefix-hit blocks, "
              f"{report.cow_blocks} copy-on-writes, "
              f"{report.n_preemptions} preemptions")
    if report.draft != "none":
        print(f"spec:    drafter={report.draft} "
              f"K={args.num_draft_tokens}: "
              f"{report.accepted_tokens}/{report.drafted_tokens} drafts "
              f"accepted ({report.acceptance_rate*100:.0f}%), "
              f"{report.committed_tokens_per_step:.2f} committed "
              f"tokens/step")
    if report.ttft_wall is not None:
        itl = (f", itl p50 {report.itl_wall['p50']*1e3:.1f} ms "
               f"p99 {report.itl_wall['p99']*1e3:.1f} ms"
               if report.itl_wall else "")
        print(f"latency: ttft p50 {report.ttft_wall['p50']*1e3:.1f} ms "
              f"p99 {report.ttft_wall['p99']*1e3:.1f} ms{itl}")
    for r in report.results[:4]:
        print(f"  req {r.request_id}: {len(r.tokens)} tokens "
              f"(ttft {r.ttft_steps:.0f} steps, "
              f"latency {r.latency_steps:.0f} steps, {r.finish_reason}) "
              f"head: {r.tokens[:8].tolist()}")

    # ---- BitParticle deployment estimate ----------------------------------
    if report.deployment is not None:
        d = report.deployment
        print(f"\nBitParticle deployment estimate (modeled 45nm array, "
              f"{d['mode']}):")
        print(f"  mean weight bit sparsity (sign-magnitude): "
              f"{d['mean_bit_sparsity']:.3f}")
        print(f"  mean cycles/MAC: {d['mean_cycles_per_mac']:.2f}   "
              f"mean energy/MAC: {d['mean_mac_energy_pj']:.2f} pJ")
        for e in d["per_layer"][:6]:
            name = f"layer {e['layer']}" if e["layer"] >= 0 else "unstacked"
            print(f"    {name}: bs={e['bit_sparsity']:.3f} "
                  f"cycles={e['avg_cycles_per_mac']:.2f} "
                  f"energy={e['mac_energy_pj']:.2f} pJ")

    # ---- measured-traffic hardware estimate (--probe) ---------------------
    if report.hw_measured is not None:
        hw = report.hw_measured
        print(f"\nmeasured-traffic hardware estimate "
              f"({hw['n_samples']} sampled steps, every "
              f"{hw['probe_every']}):")
        print(f"  activation bit sparsity {hw['act_bit_sparsity']:.3f} "
              f"(value {hw['act_value_sparsity']:.3f}), weight bit "
              f"sparsity {hw['weight_bit_sparsity']:.3f}")
        print(f"  modeled array utilization "
              f"{hw['array_utilization']:.3f}")
        for m in sorted(hw["cycles"]):
            print(f"    {m}: {hw['cycles'][m]:.2f} cycles/MAC, "
                  f"{hw['mac_energy_pj'][m]:.2f} pJ/MAC")


if __name__ == "__main__":
    main()
