"""End-to-end serving driver: batched requests against a small qwen2-family
model with BitParticle W8A8 weights and an int8 KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--tokens 24] [--batch 4]
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import cost_model as cm
from repro.core import sparsity
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--mode", default="bp_exact",
                    choices=["bf16", "bp_exact", "bp_approx"])
    args = ap.parse_args()

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=4, d_model=256, d_ff=512, vocab_size=2048, head_dim=32)
    print(f"arch: qwen2-family reduced ({cfg.param_count()/1e6:.1f}M params), "
          f"mode={args.mode}")

    params = api.init(jax.random.PRNGKey(0), cfg)
    if args.mode != "bf16":
        params = quantize_dense_params(params)
        cfg = cfg.replace(matmul_mode=args.mode, kv_cache_int8=True)
        print("weights quantized to int8 (per-channel), KV cache int8")

    engine = ServingEngine(cfg, params,
                           ServeConfig(max_new_tokens=args.tokens,
                                       temperature=0.8))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 2,
                                 cfg.vocab_size)
    # warmup (compile)
    engine.generate({"tokens": prompts[:, :8]})
    res = engine.generate({"tokens": prompts})
    print(f"prefill: {res.prefill_s*1e3:.1f} ms for "
          f"{args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {res.steps} steps, "
          f"{res.decode_tokens_per_s:.1f} tokens/s (batch={args.batch})")
    print(f"sample continuation (request 0): {res.tokens[0][:12].tolist()}")

    # ---- BitParticle deployment estimate ----------------------------------
    if args.mode != "bf16":
        w_leaves = [l for l in jax.tree.leaves(params)
                    if hasattr(l, "dtype") and l.dtype == jnp.int8]
        bs = float(np.mean([float(sparsity.bit_sparsity_sign_magnitude(w))
                            for w in w_leaves[:8]]))
        cyc = cm.modeled_avg_cycles(
            "bp_exact" if args.mode == "bp_exact" else "bp_approx", bs,
            n=50_000)
        e = cm.mac_energy_pj(args.mode if args.mode != "bf16" else "bp_exact",
                             bs)
        print(f"\nBitParticle deployment estimate (modeled 45nm array):")
        print(f"  weight bit sparsity (sign-magnitude): {bs:.3f}")
        print(f"  avg cycles/MAC: {cyc:.2f}   energy/MAC: {e:.2f} pJ")
        print(f"  vs AdaS unit:  {cm.mac_energy_pj('adas', bs):.2f} pJ;  "
              f"vs BitWave: {cm.mac_energy_pj('bitwave', bs):.2f} pJ")


if __name__ == "__main__":
    main()
