"""Stand up the async front door and talk to it over real TCP.

Starts N engine replicas (shared weights, one engine each) behind the
prefix-affinity router and the stdlib asyncio HTTP/SSE server, then
drives it with the blocking client: a health check, a couple of
streaming generations with a shared system prompt (watch the prefix
cache), one request that disconnects mid-stream (watch the cancellation
lifecycle reclaim its blocks), and a final stats dump.

    PYTHONPATH=src python examples/frontdoor_server.py
    PYTHONPATH=src python examples/frontdoor_server.py --replicas 2 \
        --chunk 16 --policy affinity
    PYTHONPATH=src python examples/frontdoor_server.py --serve-only \
        --port 8080          # leave it running; curl it from elsewhere

While running with ``--serve-only`` you can hit it by hand:

    curl -s localhost:8080/healthz
    curl -s localhost:8080/v1/stats
    curl -s -X POST localhost:8080/v1/generate \
        -d '{"prompt": [1,2,3,4], "max_new_tokens": 8}'
    curl -sN -X POST localhost:8080/v1/generate \
        -d '{"prompt": [1,2,3,4], "max_new_tokens": 8, "stream": true}'
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (FrontDoor, FrontDoorClient, Replica,
                           SchedulerConfig, ServeConfig, ServingEngine,
                           SLOClass)


def build_door(args) -> FrontDoor:
    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256, head_dim=16)
    params = api.init(jax.random.PRNGKey(0), cfg)

    sched = SchedulerConfig(
        lead_window=2, policy="slo",
        slo_classes={
            "interactive": SLOClass("interactive", priority=10,
                                    ttft_target_s=0.5, itl_target_s=0.2),
            "default": SLOClass("default", priority=0)})
    replicas = []
    for i in range(args.replicas):
        # one engine per replica (cancellation state is per engine);
        # params are shared — only the KV pools are private
        engine = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=args.tokens, temperature=0.0,
            cache_backend="paged", block_size=4,
            prefill_chunk=args.chunk))
        replicas.append(Replica(engine, name=f"r{i}", n_slots=2,
                                cache_T=128, num_blocks=256,
                                sched_cfg=sched))
    return FrontDoor(replicas, policy=args.policy, port=args.port)


def drive(fd: FrontDoor) -> None:
    client = FrontDoorClient("127.0.0.1", fd.port)
    print(f"listening on :{fd.port}  healthz={client.healthz()}")

    rng = np.random.default_rng(0)
    system = rng.integers(2, 200, size=16).tolist()   # shared tenant prefix

    def prompt():
        return system + rng.integers(2, 200, size=4).tolist()

    for i in range(3):
        out = client.generate(prompt(), max_new_tokens=8, stream=True,
                              slo_class="interactive")
        print(f"stream {i} via {out['replica']}: {out['tokens']} "
              f"({out['finish_reason']})")

    # hang up after 2 tokens: the server cancels into the engine and the
    # next sweep frees the slot + blocks
    out = client.generate(prompt(), max_new_tokens=8, disconnect_after=2)
    print(f"disconnected after {len(out['tokens'])} tokens "
          f"(request {out['request_id']} on {out['replica']})")

    deadline = time.time() + 30
    while time.time() < deadline:
        stats = client.stats()
        if all(r["queue_depth"] == 0 for r in stats["replicas"]):
            break
        time.sleep(0.05)
    for r in client.stats()["replicas"]:
        print(f"  {r['name']}: prefix_hit_blocks={r.get('prefix_hit_blocks')}"
              f" blocks_in_use={r.get('blocks_in_use')}"
              f" cost_hint={r['cost_hint_cycles_per_token']:.3f}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--tokens", type=int, default=8)
    p.add_argument("--chunk", type=int, default=16,
                   help="chunked-prefill bound (tokens per sync)")
    p.add_argument("--policy", default="affinity",
                   choices=("affinity", "least_loaded", "round_robin",
                            "random"))
    p.add_argument("--port", type=int, default=0,
                   help="0 picks an ephemeral port")
    p.add_argument("--serve-only", action="store_true",
                   help="start and block until Ctrl-C instead of driving "
                        "demo traffic")
    args = p.parse_args()

    fd = build_door(args).start()
    try:
        if args.serve_only:
            print(f"front door listening on :{fd.port} (Ctrl-C to stop)")
            while True:
                time.sleep(1)
        else:
            drive(fd)
    except KeyboardInterrupt:
        pass
    finally:
        reports = fd.stop()
        for name, rep in sorted(reports.items()):
            print(f"{name}: requests={len(rep.results)} steps={rep.steps} "
                  f"cancelled={rep.n_cancelled} "
                  f"chunk_tokens={rep.chunk_tokens}")


if __name__ == "__main__":
    main()
