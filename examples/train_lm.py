"""End-to-end training driver with checkpoint/resume, spike rejection and
compressed-gradient data parallelism on the synthetic pipeline.

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --steps 200   # resumes at 100

    # ~100M-param configuration (slow on CPU; the default is laptop-sized):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 5
"""

import argparse
import shutil

import jax

from repro.configs.base import get_arch
from repro.data.pipeline import DataConfig
from repro.distributed.quasi_sync import ClusterConfig, cluster_utilization
from repro.train import optimizer as opt_lib
from repro.train.train_loop import TrainConfig, Trainer

PRESETS = {
    "tiny": dict(num_layers=2, d_model=128, d_ff=256, vocab_size=1024,
                 head_dim=32, seq=128, batch=8),
    "10m": dict(num_layers=4, d_model=320, d_ff=864, vocab_size=4096,
                head_dim=64, seq=256, batch=8),
    "100m": dict(num_layers=12, d_model=768, d_ff=2048, vocab_size=8192,
                 head_dim=64, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    arch = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=p["num_layers"], d_model=p["d_model"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], head_dim=p["head_dim"])
    print(f"model: {arch.param_count()/1e6:.1f}M params "
          f"(preset={args.preset})")
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    tc = TrainConfig(
        total_steps=args.steps, ckpt_every=25, ckpt_dir=args.ckpt_dir,
        compress_grads=args.compress_grads, log_every=10,
        optimizer=opt_lib.OptimizerConfig(peak_lr=3e-3, warmup_steps=20,
                                          total_steps=args.steps))
    dc = DataConfig(vocab_size=arch.vocab_size, seq_len=p["seq"],
                    global_batch=p["batch"])
    trainer = Trainer(arch, tc, dc, init_key=jax.random.PRNGKey(0))
    if trainer.start_step:
        print(f"resumed from checkpoint at step {trainer.start_step}")

    def log(step, metrics):
        print(f"step {step:4d}  loss={metrics['loss']:.4f}  "
              f"lr={metrics['lr']:.2e}  gnorm={metrics['grad_norm']:.2f}  "
              f"{metrics['step_time_s']*1e3:.0f} ms")

    end, hist = trainer.run(on_metrics=log)
    losses = [l for _, l in hist]
    print(f"\ndone at step {end}; loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"rejected steps: {trainer.total_skips}")

    # --- what would quasi-sync buy this job at fleet scale? ----------------
    strict = cluster_utilization(ClusterConfig(E=0, Q=0), n_rounds=100)
    elastic = cluster_utilization(ClusterConfig(E=3, Q=2), n_rounds=100)
    print(f"\nfleet-scale quasi-sync estimate (8 hosts x 32 DP groups, "
          f"lognormal stragglers):")
    print(f"  strict sync  E0Q0: worker utilization "
          f"{strict.pe_utilization:.3f}")
    print(f"  quasi-sync   E3Q2: worker utilization "
          f"{elastic.pe_utilization:.3f} "
          f"({(elastic.pe_utilization/strict.pe_utilization-1)*100:+.1f}%)")


if __name__ == "__main__":
    main()
