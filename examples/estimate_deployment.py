"""Price a real model's layers on the modeled BitParticle accelerator:
per-layer bit/value sparsity -> cycles, energy, and the exact-vs-approx /
vs-AdaS / vs-BitWave comparison (the paper's evaluation flow applied to an
LM from this repo's zoo).

    PYTHONPATH=src python examples/estimate_deployment.py [--arch qwen2-1.5b]
"""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import cost_model as cm
from repro.core import quant, sparsity
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                cfg.vocab_size)
    mod = api.module_for(cfg)
    if cfg.family == "audio":
        batch = {"tokens": tokens,
                 "src_embeds": jax.random.normal(jax.random.PRNGKey(2),
                                                 (2, 32, cfg.d_model),
                                                 jnp.bfloat16)}
        from repro.models import encdec
        acts = encdec.encode(params, cfg, batch["src_embeds"])
    else:
        acts, _, _ = mod.forward(params, cfg, {"tokens": tokens})
    a_q, _ = quant.quantize_per_tensor(jnp.asarray(acts, jnp.float32))

    print(f"{'layer':42s} {'bitsp':>6s} {'valsp':>6s} {'cyc':>6s} "
          f"{'cyc~':>6s} {'pJ/MAC':>7s}")
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    rows = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if leaf.ndim < 2 or not name.endswith("w"):
            continue
        w_q, _ = quant.quantize_per_tensor(jnp.asarray(leaf, jnp.float32))
        bs = float(sparsity.bit_sparsity_sign_magnitude(w_q))
        vs = float(sparsity.value_sparsity(a_q))
        cyc = cm.avg_cycles_for_tensors(w_q, a_q, approx=False)
        cyc_a = cm.avg_cycles_for_tensors(w_q, a_q, approx=True)
        pj = cm.mac_energy_pj("bp_exact", bs)
        rows.append((bs, vs, cyc, cyc_a, pj))
        if len(rows) <= 12:
            print(f"{name[-42:]:42s} {bs:6.3f} {vs:6.3f} {cyc:6.2f} "
                  f"{cyc_a:6.2f} {pj:7.2f}")
    bs_m = float(np.mean([r[0] for r in rows]))
    print(f"\nmean weight bit sparsity {bs_m:.3f} over {len(rows)} kernels")
    for unit in ("bp_exact", "bp_approx", "bitwave", "adas"):
        c = cm.modeled_avg_cycles(
            "bit_serial" if unit == "adas" else unit, bs_m, n=50_000)
        print(f"  {unit:10s} cycles/MAC={c:5.2f}  "
              f"energy/MAC={cm.mac_energy_pj(unit, bs_m):5.2f} pJ  "
              f"area={cm.AREA_UM2[unit]:8.1f} um^2")


if __name__ == "__main__":
    main()
