"""Price a real model's layers on the modeled BitParticle accelerator:
per-layer bit/value sparsity -> cycles, energy, and the exact-vs-approx /
vs-AdaS / vs-BitWave comparison (the paper's evaluation flow applied to an
LM from this repo's zoo).

    PYTHONPATH=src python examples/estimate_deployment.py [--arch qwen2-1.5b]
    PYTHONPATH=src python examples/estimate_deployment.py --measured run.jsonl

``--measured`` switches from the synthetic single-forward estimate to the
``hw_estimate`` records a probed serve wrote (``serve_lm.py --probe K
--metrics run.jsonl`` or ``benchmarks/production_mix.py --telemetry DIR``):
it averages the measured-traffic modeled cycles and prints them against the
cited Table III ladder interpolated at the same operating point, so the
delta shows how far live-traffic sparsity sits from the paper's benchmark
conditions.
"""

import argparse
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import cost_model as cm
from repro.core import quant, sparsity
from repro.models import api


def measured_report(path: str) -> int:
    from repro.serving import PROBE_METHODS, read_jsonl

    recs = [r for r in read_jsonl(path) if r.get("kind") == "hw_estimate"]
    if not recs:
        print(f"estimate_deployment: no hw_estimate records in {path} "
              f"(serve with a SparsityProbe attached, e.g. "
              f"serve_lm.py --probe 2 --metrics {path})", file=sys.stderr)
        return 1
    n = len(recs)
    phases = sorted({r["phase"] for r in recs})
    act_bs = float(np.mean([r["act_bit_sparsity"] for r in recs]))
    act_vs = float(np.mean([r["act_value_sparsity"] for r in recs]))
    w_bs = float(np.mean([r["weight_bit_sparsity"] for r in recs]))
    util = float(np.mean([r["array_utilization"] for r in recs]))
    per_layer = np.mean([r["per_layer_act_bit_sparsity"] for r in recs],
                        axis=0)

    print(f"measured-traffic deployment estimate: {n} sampled steps "
          f"({'/'.join(phases)}) from {path}")
    print(f"  activation bit sparsity {act_bs:.3f} "
          f"(value {act_vs:.3f}), weight bit sparsity {w_bs:.3f}, "
          f"modeled array utilization {util:.3f}")
    print("  per-layer activation bit sparsity: "
          + " ".join(f"{v:.3f}" for v in per_layer))

    # the cited ladder is indexed by one shared sparsity level -> interpolate
    # at the measured operating point (mean of the two factors' sparsity,
    # the same rule SparsityProbe.fold uses for energy)
    op_bs = 0.5 * (act_bs + w_bs)
    levels = np.asarray(cm.SPARSITY_LEVELS)
    print(f"\n  {'unit':10s} {'measured':>9s} {'tableIII':>9s} "
          f"{'delta':>7s}   {'pJ/MAC':>7s}  (table interpolated at "
          f"bs={op_bs:.3f})")
    for m in PROBE_METHODS:
        meas = float(np.mean([r["cycles"][m] for r in recs]))
        table = float(np.interp(op_bs, levels,
                                np.asarray(cm.PAPER_AVG_CYCLES[m])))
        pj = float(np.mean([r["mac_energy_pj"][m] for r in recs]))
        print(f"  {m:10s} {meas:9.2f} {table:9.2f} "
              f"{(meas - table) / table * 100:+6.1f}%   {pj:7.2f}")
    print("\n  deltas reflect live-traffic sparsity (and the wider "
          "interpolation grid), not a change in the cost model itself")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--measured", default=None, metavar="JSONL",
                    help="aggregate the hw_estimate records of a probed "
                         "serve instead of the synthetic estimate")
    args = ap.parse_args()
    if args.measured:
        sys.exit(measured_report(args.measured))

    cfg = get_arch(args.arch).reduced()
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                cfg.vocab_size)
    mod = api.module_for(cfg)
    if cfg.family == "audio":
        batch = {"tokens": tokens,
                 "src_embeds": jax.random.normal(jax.random.PRNGKey(2),
                                                 (2, 32, cfg.d_model),
                                                 jnp.bfloat16)}
        from repro.models import encdec
        acts = encdec.encode(params, cfg, batch["src_embeds"])
    else:
        acts, _, _ = mod.forward(params, cfg, {"tokens": tokens})
    a_q, _ = quant.quantize_per_tensor(jnp.asarray(acts, jnp.float32))

    print(f"{'layer':42s} {'bitsp':>6s} {'valsp':>6s} {'cyc':>6s} "
          f"{'cyc~':>6s} {'pJ/MAC':>7s}")
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    rows = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if leaf.ndim < 2 or not name.endswith("w"):
            continue
        w_q, _ = quant.quantize_per_tensor(jnp.asarray(leaf, jnp.float32))
        bs = float(sparsity.bit_sparsity_sign_magnitude(w_q))
        vs = float(sparsity.value_sparsity(a_q))
        cyc = cm.avg_cycles_for_tensors(w_q, a_q, approx=False)
        cyc_a = cm.avg_cycles_for_tensors(w_q, a_q, approx=True)
        pj = cm.mac_energy_pj("bp_exact", bs)
        rows.append((bs, vs, cyc, cyc_a, pj))
        if len(rows) <= 12:
            print(f"{name[-42:]:42s} {bs:6.3f} {vs:6.3f} {cyc:6.2f} "
                  f"{cyc_a:6.2f} {pj:7.2f}")
    bs_m = float(np.mean([r[0] for r in rows]))
    print(f"\nmean weight bit sparsity {bs_m:.3f} over {len(rows)} kernels")
    for unit in ("bp_exact", "bp_approx", "bitwave", "adas"):
        c = cm.modeled_avg_cycles(
            "bit_serial" if unit == "adas" else unit, bs_m, n=50_000)
        print(f"  {unit:10s} cycles/MAC={c:5.2f}  "
              f"energy/MAC={cm.mac_energy_pj(unit, bs_m):5.2f} pJ  "
              f"area={cm.AREA_UM2[unit]:8.1f} um^2")


if __name__ == "__main__":
    main()
