"""Quickstart: the BitParticle pipeline end to end in two minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitparticle as bp
from repro.core import cost_model as cm
from repro.core.array_sim import ArrayConfig, run_experiment
from repro.core.bp_matmul import bp_matmul_int
from repro.kernels.bitparticle_matmul import bp_matmul as bp_matmul_pallas


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main():
    # ---- 1. one MAC through the particlized datapath ---------------------
    section("1. Particlized multiplication (paper Fig. 4)")
    a, w = -93, 57
    prod, pps, cycles = bp.assemble_partial_products(a, w)
    print(f"a={a}, w={w}: particles(a)={list(np.asarray(bp.particlize(abs(a))))}")
    print(f"IR matrix:\n{np.asarray(bp.ir_matrix(abs(a), abs(w)))}")
    print(f"partial products per cycle (set0, set1): {pps}")
    print(f"product={prod} (check: {a*w}), cycles={cycles} "
          f"(model: {int(bp.mac_cycles(a, w))})")
    print(f"approx product={int(bp.multiply_approx(a, w))} "
          f"(drops IR groups 0 and 1-4)")

    # ---- 2. quantized matmul in all three numerics modes ------------------
    section("2. W8A8 matmul: exact == int8; approx within 81*K")
    key = jax.random.PRNGKey(0)
    A = jax.random.randint(key, (8, 64), -127, 128, dtype=jnp.int32).astype(jnp.int8)
    W = jax.random.randint(jax.random.fold_in(key, 1), (64, 16), -127, 128,
                           dtype=jnp.int32).astype(jnp.int8)
    exact = bp_matmul_int(A, W, "bp_exact")
    approx = bp_matmul_int(A, W, "bp_approx")
    print(f"max |exact - int_matmul| = "
          f"{int(jnp.abs(exact - A.astype(jnp.int32) @ W.astype(jnp.int32)).max())}")
    print(f"max |approx - exact| = {int(jnp.abs(approx - exact).max())} "
          f"(bound: 81*K = {81*64})")

    # ---- 3. the Pallas TPU kernel (interpret mode on CPU) -----------------
    section("3. Pallas kernel (pl.pallas_call, interpret=True)")
    out = bp_matmul_pallas(A, W, approx=True, interpret=True, block_m=8)
    print(f"kernel == jnp reference: {bool((out == approx).all())}")

    # ---- 4. quasi-synchronous MAC array ------------------------------------
    section("4. Quasi-sync array: E/Q elasticity (paper Fig. 8)")
    for E, Q in [(0, 0), (3, 2)]:
        r = run_experiment(0, ArrayConfig(E=E, Q=Q), 128, bit_sparsity=0.7)
        print(f"E{E}Q{Q}: PE utilization={r.pe_utilization:.3f}, "
              f"cycles/step={r.avg_cycles_per_step:.3f}")

    # ---- 5. efficiency vs the baselines ------------------------------------
    section("5. Table III reproduction (normalized to AdaS)")
    t = cm.table3("paper")
    print("area eff  @60% bit sparsity:",
          {k: round(v["area_eff"][1], 2) for k, v in t.items()})
    print("energy eff@60% bit sparsity:",
          {k: round(v["energy_eff"][1], 2) for k, v in t.items()})
    print("our modeled BP cycles vs paper:",
          round(cm.modeled_avg_cycles("bp_exact", 0.6, n=50_000), 3),
          "vs", cm.PAPER_AVG_CYCLES["bp_exact"][1])


if __name__ == "__main__":
    main()
